"""The paper's worked example (Fig. 2 / Table 1 / Examples 1-3) as tests.

We reconstruct the visibility graph of Figure 2 (5 convex vertices A..E with
the edge weights implied by Table 1) and check that our hub labeling answers
the paper's own query: d(E, A) = 10 via common hubs {B, E} with
min(5.1 + 6.1, 10 + 0) = 10  (Example 1).
"""

import numpy as np
import pytest

from repro.core.hublabel import build_hub_labels
from repro.core.visgraph import VisGraph, dijkstra

A, B, C, D, E = range(5)


def _paper_graph():
    # edges of Fig. 2: (A-B 5.1), (A-E 10), (B-C 5.1), (B-D 5.4),
    # (B-E 6.1), (D-E 5.3)
    edges = {(A, B): 5.1, (A, E): 10.0, (B, C): 5.1, (B, D): 5.4,
             (B, E): 6.1, (D, E): 5.3}
    nodes = np.zeros((5, 2))        # coordinates unused by HL itself
    adj_idx = [[] for _ in range(5)]
    adj_w = [[] for _ in range(5)]
    for (u, v), w in edges.items():
        adj_idx[u].append(v)
        adj_w[u].append(w)
        adj_idx[v].append(u)
        adj_w[v].append(w)
    return VisGraph(scene=None, nodes=nodes, adj_idx=adj_idx, adj_w=adj_w)


def test_example1_distance_E_A():
    g = _paper_graph()
    hl = build_hub_labels(g)
    assert hl.query(E, A) == pytest.approx(10.0)          # the paper's answer
    # and the other pairs against Dijkstra
    for s in range(5):
        dist, _ = dijkstra(g, s)
        for t in range(5):
            assert hl.query(s, t) == pytest.approx(dist[t], abs=1e-9)


def test_coverage_via_hub_B():
    """Table 1: B is the top hub (highest degree) and covers most pairs."""
    g = _paper_graph()
    hl = build_hub_labels(g)
    # B has degree 4 -> first in the degree ordering, so every vertex keeps
    # a B label (as in the paper's Table 1 where B appears in every H(v))
    for v in range(5):
        hubs = hl.labels[v][0]
        assert B in hubs


def test_label_sizes_small():
    """2-hop cover of a 5-vertex graph needs few labels (paper Table 1: 10)."""
    g = _paper_graph()
    hl = build_hub_labels(g)
    assert hl.label_count() <= 12
