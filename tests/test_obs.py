"""Telemetry subsystem (DESIGN.md §12): histogram quantile correctness,
request-span completeness through the serving stack, generation-tagged
series reset across hot-swaps, and export fidelity.

The serving-path tests drive a private ``MetricsRegistry`` per server (the
views accept one), so nothing here depends on — or pollutes — the
process-wide ``obs.REGISTRY`` other tests record into.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.indexing import SwappableEngine
from repro.serving.batcher import CoalescingBatcher, QueueFull
from repro.serving.engine import PathServer
from repro.serving.query_engine import QueryEngine


class _KeyedEngine(QueryEngine):
    """Deterministic 4-key engine (see tests/test_batcher.py)."""

    name = "keyed"
    static_shapes = True
    num_buckets = 4

    def __init__(self, val: float = 0.0):
        self.val = val

    def buckets_of(self, s, t):
        return (np.asarray(s)[:, 0].astype(np.int64) % 4).astype(np.int32)

    def bucket_width(self, bucket: int) -> int:
        return 128

    def batch(self, s, t, bucket: int = 0):
        return (np.asarray(s)[:, 0] + 1000.0 * self.val).astype(np.float32)

    def batch_argmin(self, s, t, bucket: int = 0):
        d = self.batch(s, t, bucket)
        z = np.zeros(len(d), np.int32)
        return d, z, z, z, z


def _pts(xs):
    xs = np.asarray(xs, np.float32)
    return np.stack([xs, np.zeros_like(xs)], axis=1)


def _traced_server(engine, **kw):
    """Server over a private registry with every request head-sampled."""
    tel = obs.Telemetry(registry=obs.MetricsRegistry(), sample_rate=1.0)
    return PathServer(engine, telemetry=tel, **kw), tel


# --------------------------------------------------------------- histograms

def test_histogram_quantiles_exact_on_bucket_bounds():
    """When every sample sits on a bucket bound, rank-based readback must
    agree exactly with numpy's inverted-CDF quantile."""
    bounds = np.array([1.0, 2.0, 4.0, 8.0, 16.0, 32.0])
    rng = np.random.default_rng(5)
    data = rng.choice(bounds, size=257)
    h = obs.Histogram("t_ms", (), bounds=bounds)
    h.record_many(data)
    for q in (0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        want = float(np.quantile(data, q, method="inverted_cdf"))
        assert h.quantile(q) == want, q
    assert h.count == len(data)
    assert h.sum == pytest.approx(float(data.sum()))


def test_histogram_quantile_bounded_by_bucket_resolution():
    """Off-bound samples: the readback overshoots by at most one bucket
    ratio and never leaves the observed [min, max] range."""
    bounds = obs.log_bounds(1e-3, 1e3, per_decade=8)
    ratio = 10.0 ** (1.0 / 8.0)
    rng = np.random.default_rng(11)
    data = rng.lognormal(mean=1.0, sigma=1.2, size=4096)
    h = obs.Histogram("t_ms", (), bounds=bounds)
    h.record_many(data)
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = float(np.quantile(data, q, method="inverted_cdf"))
        got = h.quantile(q)
        assert exact <= got * (1.0 + 1e-9) <= exact * ratio * (1.0 + 1e-9)
        assert data.min() <= got <= data.max()


def test_histogram_merge_matches_combined_recording():
    bounds = np.array([1.0, 2.0, 4.0, 8.0])
    a = obs.Histogram("x", (), bounds=bounds)
    b = obs.Histogram("x", (), bounds=bounds)
    a.record_many([0.5, 1.0, 3.0])
    b.record_many([2.0, 9.0, 100.0])            # overflow bucket included
    both = obs.Histogram("x", (), bounds=bounds)
    both.record_many([0.5, 1.0, 3.0, 2.0, 9.0, 100.0])
    a.merge(b)
    assert a.count == both.count and a.sum == pytest.approx(both.sum)
    assert np.array_equal(a.counts, both.counts)
    assert a.min == both.min and a.max == both.max
    for q in (0.5, 0.95):
        assert a.quantile(q) == both.quantile(q)


def test_head_sampler_is_deterministic():
    s = obs.HeadSampler(rate=0.25, slow_ms=0.0)
    picks = [s.sample() for _ in range(100)]
    assert sum(picks) == 25
    assert picks == [i % 4 == 3 for i in range(100)]   # leaky bucket, no RNG
    assert not any(obs.HeadSampler(rate=0.0).sample() for _ in range(10))
    assert all(obs.HeadSampler(rate=1.0).sample() for _ in range(10))
    assert obs.HeadSampler(rate=0.0, slow_ms=10.0).slow(0.02)
    assert not obs.HeadSampler(rate=0.0, slow_ms=10.0).slow(0.005)


# --------------------------------------------------- span completeness (async)

def test_async_spans_complete_and_telescope():
    """Every request head-sampled: each trace is a closed span tree with
    the full async taxonomy and stage attribution summing to e2e."""
    srv, tel = _traced_server(_KeyedEngine(), batch_size=8)
    b = CoalescingBatcher(srv, autostart=False)
    rng = np.random.default_rng(3)
    xs = rng.integers(0, 64, size=48).astype(np.float32)
    tickets = [b.submit(_pts(xs[i:i + 3]), _pts(xs[i:i + 3]))
               for i in range(0, 48, 3)]
    b.start()
    b.flush()
    assert b.drain(timeout=10)
    b.close()
    for tk in tickets:
        tk.result(timeout=1)
    traces = tel.spans.traces("async")
    # one trace per (dispatched group, ticket) pair — a submit whose keys
    # split across groups is traced once per group it rode in
    assert len(traces) >= len(tickets)
    for tr in traces:
        assert tr.closed and tr.complete(obs.ASYNC_STAGES)
        assert tr.e2e_seconds > 0
        assert abs(tr.stage_sum - tr.e2e_seconds) <= 0.05 * tr.e2e_seconds
        tree = tr.tree()
        assert [c["name"] for c in tree["children"]] == list(obs.ASYNC_STAGES)
        assert tree["attrs"]["outcome"] == "ok"
    # stage latency histograms saw every retired group
    for st in ("queue_wait", "device_join", "reply"):
        hs = tel.registry.find("stage_ms", stage=st)
        assert sum(h.count for h in hs) == srv.stats.batches
    lat = tel.registry.find("request_latency_ms")
    assert sum(h.count for h in lat) == len(traces)


def test_requeued_request_span_covers_swap(monkeypatch=None):
    """A group admitted under gen 0 and dispatched after a swap still
    produces a complete span, with the requeue recorded on the trace and
    in the event log."""
    old, new = _KeyedEngine(1.0), _KeyedEngine(2.0)
    sw = SwappableEngine(old)
    srv, tel = _traced_server(sw, batch_size=8)
    b = CoalescingBatcher(srv, autostart=False)
    xs = np.full(8, 4.0) + np.arange(8) * 4
    tk = b.submit(_pts(xs), _pts(xs))            # queued under gen 0
    sw.swap(new)                                 # published before dispatch
    b.start()
    tk.result(timeout=10)
    b.close()
    (tr,) = tel.spans.traces("async")
    assert tr.complete(obs.ASYNC_STAGES)
    assert tr.attrs["requeues"] == 1
    assert tr.attrs["generation"] == 1
    assert abs(tr.stage_sum - tr.e2e_seconds) <= 0.05 * tr.e2e_seconds
    (ev,) = tel.events.events("requeue")
    assert ev["from_gen"] == 0 and ev["to_gen"] == 1


def test_shed_request_traced_with_shed_outcome():
    srv, tel = _traced_server(_KeyedEngine(), batch_size=8)
    b = CoalescingBatcher(srv, autostart=False, max_queue=4, policy="shed")
    b.submit(_pts([0.0, 1.0]), _pts([0.0, 1.0]))
    with pytest.raises(QueueFull):
        b.submit(_pts([2.0, 3.0, 4.0]), _pts([2.0, 3.0, 4.0]))
    b.start()
    b.flush()
    b.drain(timeout=10)
    b.close()
    shed = [t for t in tel.spans.traces("async")
            if t.attrs["outcome"] == "shed"]
    assert len(shed) == 1
    assert shed[0].closed and shed[0].complete(obs.ASYNC_STAGES)
    (ev,) = tel.events.events("shed")
    assert ev["n"] == 3 and ev["max_queue"] == 4
    assert srv.stats.shed == 3


def test_sync_spans_complete_and_telescope():
    srv, tel = _traced_server(_KeyedEngine(), batch_size=8)
    xs = np.arange(12, dtype=np.float32)
    srv.query(_pts(xs), _pts(xs))
    (tr,) = tel.spans.traces("sync")
    assert tr.closed and tr.complete(obs.SYNC_STAGES)
    assert abs(tr.stage_sum - tr.e2e_seconds) <= 0.05 * tr.e2e_seconds
    (h,) = tel.registry.find("sync_batch_ms")
    assert h.count == 1


# ------------------------------------------- registry across hot-swap (load)

def test_registry_series_reset_per_generation_under_load():
    """Per-bucket series are generation-tagged: after a swap the live view
    rows restart at zero while the retired generation's series stay frozen
    in the registry (the serve totals keep accumulating)."""
    old, new = _KeyedEngine(1.0), _KeyedEngine(2.0)
    sw = SwappableEngine(old)
    srv, tel = _traced_server(sw, batch_size=8)
    b = CoalescingBatcher(srv, autostart=True, max_wait_ms=2.0)
    xs = np.full(8, 4.0) + np.arange(8) * 4      # key 0, one full batch
    b.submit(_pts(xs), _pts(xs)).result(timeout=10)
    pb0 = srv.stats.per_bucket[0]
    assert pb0.queries == 8
    sw.swap(new)
    b.submit(_pts(xs), _pts(xs)).result(timeout=10)
    b.close()
    pb1 = srv.stats.per_bucket[0]
    assert pb1 is not pb0                        # fresh row, new generation
    assert pb1.labels["gen"] == "1" and pb0.labels["gen"] == "0"
    assert pb1.queries == 8                      # restarted, not resumed
    assert pb0.queries == 8                      # retired series frozen
    assert srv.stats.queries == 16               # serve totals accumulate
    assert srv.stats.swaps == 1
    gens = {dict(m.labels)["gen"]
            for m in tel.registry.series("bucket_queries_total")}
    assert gens == {"0", "1"}


# ------------------------------------------------------------------- export

def test_prometheus_export_reproduces_serve_stats():
    srv, tel = _traced_server(_KeyedEngine(), batch_size=8)
    xs = np.arange(20, dtype=np.float32)
    srv.query(_pts(xs), _pts(xs))
    text = obs.prometheus_text(tel.registry)
    parsed = obs.parse_prometheus(text)          # raises on malformed lines

    def total(name):
        return sum(parsed[name].values())

    assert total("serve_queries_total") == srv.stats.queries == 20
    assert total("serve_batches_total") == srv.stats.batches
    assert total("bucket_queries_total") == 20
    assert total("serve_seconds_total") == pytest.approx(
        srv.stats.seconds, rel=1e-9)
    # histograms export cumulative buckets with a +Inf terminal
    inf_rows = [k for k in parsed["sync_batch_ms_bucket"]
                if dict(k)["le"] == "+Inf"]
    assert inf_rows and sum(
        parsed["sync_batch_ms_bucket"][k] for k in inf_rows) == 1
    assert total("sync_batch_ms_count") == 1


def test_json_snapshot_round_trips():
    reg = obs.MetricsRegistry()
    reg.counter("a_total", srv="s").inc(3)
    reg.histogram("b_ms").record(2.5)
    snap = json.loads(obs.json_snapshot(reg, extra_field="x"))
    assert snap["extra_field"] == "x"
    (c,) = snap["counters"]
    assert c["name"] == "a_total" and c["value"] == 3
    (h,) = snap["histograms"]
    assert h["count"] == 1 and h["sum"] == 2.5


def test_event_log_ring_and_jsonl(tmp_path):
    ev = obs.EventLog(capacity=4)
    ev.emit("swap", generation=1, decision="replan")
    ev.emit("drift", drift=0.4)
    for i in range(4):
        ev.emit("shed", n=i)
    assert ev.counts() == {"shed": 4}            # ring evicted the oldest
    assert [e["n"] for e in ev.events("shed")] == [0, 1, 2, 3]
    seqs = [e["seq"] for e in ev.events()]
    assert seqs == sorted(seqs)
    p = tmp_path / "events.jsonl"
    assert ev.dump_jsonl(str(p)) == 4
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert [ln["kind"] for ln in lines] == ["shed"] * 4
    ev.enabled = False
    assert ev.emit("swap") is None
    assert ev.counts() == {"shed": 4}
