"""Hub labeling: Eq. (1) correctness, coverage, next-hop unwinding."""

import numpy as np
import pytest

from repro.core.hublabel import build_hub_labels
from repro.core.maps import make_map
from repro.core.visgraph import build_visgraph, dijkstra


@pytest.mark.parametrize("mapname,seed", [
    ("rooms-S", 1), ("maze-S", 2), ("scatter-S", 3)])
def test_hl_query_matches_dijkstra(mapname, seed):
    scene = make_map(mapname, seed=seed)
    g = build_visgraph(scene)
    hl = build_hub_labels(g)
    for s in range(0, g.num_nodes, 3):
        dist, _ = dijkstra(g, s)
        for t in range(g.num_nodes):
            got = hl.query(s, t)
            if np.isfinite(dist[t]):
                assert got == pytest.approx(dist[t], abs=1e-9)
            else:
                assert not np.isfinite(got)


def test_labels_sorted_and_self_label(graph_s, hl_s):
    for v in range(graph_s.num_nodes):
        hs, ds, nh = hl_s.labels[v]
        assert (np.diff(hs) > 0).all()           # strictly sorted, unique hubs
        k = np.searchsorted(hs, v)
        assert hs[k] == v and ds[k] == 0.0 and nh[k] == v  # canonical self label


def test_unwind_reconstructs_label_distance(graph_s, hl_s):
    nodes = graph_s.nodes
    checked = 0
    for v in range(graph_s.num_nodes):
        hs, ds, _ = hl_s.labels[v]
        for h, d in zip(hs[:5], ds[:5]):
            seq = hl_s.unwind(v, int(h))
            assert seq[0] == v and seq[-1] == h
            plen = sum(np.linalg.norm(nodes[a] - nodes[b])
                       for a, b in zip(seq, seq[1:]))
            assert plen == pytest.approx(d, abs=1e-9)
            checked += 1
    assert checked > 0


def test_coverage_property(graph_s, hl_s):
    """For every reachable pair some common hub lies ON a shortest path."""
    for s in range(0, graph_s.num_nodes, 5):
        dist, _ = dijkstra(graph_s, s)
        for t in range(graph_s.num_nodes):
            if not np.isfinite(dist[t]) or s == t:
                continue
            hs, ds, _ = hl_s.labels[s]
            ht, dt, _ = hl_s.labels[t]
            common, ia, ib = np.intersect1d(hs, ht, return_indices=True)
            assert len(common) > 0
            best = (ds[ia] + dt[ib]).min()
            assert best == pytest.approx(dist[t], abs=1e-9)
