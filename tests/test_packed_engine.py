"""Batched JAX query engine vs the exact host oracle, at several budgets."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import (pack_index, query_batch, query_batch_argmin,
                               locate_regions)
from repro.core.query import query


@pytest.fixture(scope="module")
def packed_and_truth(scene_s, graph_s, hl_s, queries_s):
    idx = build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)
    truth = np.array([query(idx, s, t, want_path=False)[0]
                      for s, t in zip(queries_s.s, queries_s.t)])
    return idx, truth


def test_locate_regions_matches_host(packed_and_truth, queries_s):
    idx, _ = packed_and_truth
    pk = pack_index(idx)
    live = sorted(idx.regions.keys())
    row_of = {rid: i for i, rid in enumerate(live)}
    rows = np.asarray(locate_regions(pk, jnp.asarray(queries_s.s)))
    for p, row in zip(queries_s.s, rows):
        assert row_of[idx.region_of_point(p).rid] == row


@pytest.mark.parametrize("use_kernels", [False, True])
def test_query_batch_matches_host(packed_and_truth, queries_s, use_kernels):
    idx, truth = packed_and_truth
    pk = pack_index(idx)
    d = np.asarray(query_batch(pk, jnp.asarray(queries_s.s),
                               jnp.asarray(queries_s.t),
                               use_kernels=use_kernels))
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)


# (compressed-index slab-vs-oracle identity moved to the conformance table
# in test_conformance.py — slab backend + host anchor on ``compressed_s``)


def test_compression_shrinks_device_tensor(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)
    full = pack_index(idx).device_bytes()
    compress_to_fraction(idx, 0.2)
    small = pack_index(idx).device_bytes()
    assert small < full


def test_argmin_distances_match(packed_and_truth, queries_s):
    idx, truth = packed_and_truth
    pk = pack_index(idx)
    d, covis, via_s, hub, via_t = query_batch_argmin(
        pk, jnp.asarray(queries_s.s), jnp.asarray(queries_s.t))
    np.testing.assert_allclose(np.asarray(d), truth, rtol=1e-4, atol=1e-4)
    # winning labels must be real (not pads) for reachable non-covisible pairs
    m = ~np.asarray(covis) & np.isfinite(truth)
    assert (np.asarray(via_s)[m] >= 0).all()
    assert (np.asarray(via_t)[m] >= 0).all()


def test_packed_pytree_roundtrip(packed_and_truth):
    import jax
    idx, _ = packed_and_truth
    pk = pack_index(idx)
    leaves, treedef = jax.tree.flatten(pk)
    pk2 = jax.tree.unflatten(treedef, leaves)
    assert pk2.nx == pk.nx and pk2.label_width == pk.label_width
