"""Mamba-2 SSD: chunked scan == sequential recurrence == step decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # test dep (pyproject [test]); skip, not error
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm as S

CFG = get_config("mamba2-780m").reduced()
KEY = jax.random.PRNGKey(1)


def _rand_inputs(key, B, Sq, cfg=CFG):
    s = cfg.ssm
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, Sq, s.n_heads, s.head_dim))
    Bm = jax.random.normal(ks[1], (B, Sq, s.state_dim))
    Cm = jax.random.normal(ks[2], (B, Sq, s.state_dim))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, Sq, s.n_heads)))
    log_dA = -jnp.exp(jax.random.normal(ks[4], (B, Sq, s.n_heads)) * 0.2) * dt
    return x, Bm, Cm, dt, log_dA


@pytest.mark.parametrize("Sq", [16, 32, 64])
def test_chunked_equals_sequential(Sq):
    x, Bm, Cm, dt, ld = _rand_inputs(KEY, 2, Sq)
    y1, st1 = S.ssd_chunked(CFG, x, Bm, Cm, dt, ld)
    y2, st2 = S.ssd_sequential(CFG, x, Bm, Cm, dt, ld)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=1e-4, atol=1e-5)


def test_chunked_with_initial_state():
    x, Bm, Cm, dt, ld = _rand_inputs(KEY, 2, 32)
    s = CFG.ssm
    init = jax.random.normal(jax.random.PRNGKey(9),
                             (2, s.n_heads, s.state_dim, s.head_dim))
    y1, st1 = S.ssd_chunked(CFG, x, Bm, Cm, dt, ld, init_state=init)
    y2, st2 = S.ssd_sequential(CFG, x, Bm, Cm, dt, ld, init_state=init)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_forward_then_decode_continuation():
    """Prefill S tokens with the chunked path, continue 4 steps with the
    O(1) decode — must equal one full forward over S+4."""
    params = S.init_ssm(CFG, KEY, 1, jnp.float32)
    p = jax.tree.map(lambda a: a[0], params)
    B, Sq, extra = 2, 32, 4
    xfull = jax.random.normal(KEY, (B, Sq + extra, CFG.d_model))

    yfull, _ = S.ssm_forward(CFG, p, xfull)
    ypre, (conv, state) = S.ssm_forward(CFG, p, xfull[:, :Sq])
    ys = [ypre]
    for i in range(extra):
        yi, conv, state = S.ssm_decode_step(CFG, p, xfull[:, Sq + i:Sq + i + 1],
                                            conv, state)
        ys.append(yi)
    ycat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(ycat), np.asarray(yfull),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_ssd_state_decay_property(seed):
    """With dt -> 0 the SSD output must vanish (pure decay, no input)."""
    key = jax.random.PRNGKey(seed)
    x, Bm, Cm, dt, ld = _rand_inputs(key, 1, 16)
    zero_dt = jnp.zeros_like(dt)
    y, stf = S.ssd_chunked(CFG, x, Bm, Cm, zero_dt, jnp.zeros_like(ld))
    assert float(jnp.abs(y).max()) < 1e-5
    assert float(jnp.abs(stf).max()) < 1e-5
