"""Adaptive index subsystem: recorder -> planner -> hot-swap correctness."""

import numpy as np
import pytest

from repro.core.compression import compress_to_device_budget
from repro.core.grid import build_ehl
from repro.core.packed import bucketed_device_bytes
from repro.core.workload import cluster_queries
from repro.indexing import (BudgetPlanner, IndexManager, SwappableEngine,
                            WorkloadRecorder)
from repro.serving.engine import PathServer
from repro.serving.query_engine import QueryEngine


# ---------------------------------------------------------------- recorder

def test_recorder_counts_decay_and_bounds():
    rec = WorkloadRecorder(nx=4, ny=4, cell_size=1.0, halflife=10.0)
    s = np.array([[0.5, 0.5], [3.5, 3.5]])
    t = np.array([[1.5, 0.5], [3.5, 0.5]])
    rec.record(s, t)
    w = rec.workload()
    assert w.shape == (16,)                      # bounded: one slot per cell
    assert rec.queries == 2
    assert w.sum() == pytest.approx(4.0)         # 4 endpoints, no decay yet
    assert w[0] == 1.0 and w[1] == 1.0           # s cells
    # out-of-bounds points clip into the grid instead of crashing
    rec.record(np.array([[99.0, -5.0]]), np.array([[2.2, 2.2]]))
    assert rec.workload().sum() == pytest.approx(
        4.0 * 0.5 ** (1 / 10.0) + 2.0)           # old mass aged one query
    d = rec.distribution()
    assert d.sum() == pytest.approx(1.0)
    rec.reset()
    assert rec.workload().sum() == 0.0 and rec.queries == 0
    # empty recorder -> uniform distribution, scores all-ones
    assert (rec.scores() == 1.0).all()
    assert rec.distribution().sum() == pytest.approx(1.0)


def test_recorder_shift_overtakes_history():
    rec = WorkloadRecorder(nx=2, ny=1, cell_size=1.0, halflife=50.0)
    left = (np.full((100, 2), 0.2), np.full((100, 2), 0.2))
    right = (np.full((100, 2), 1.8), np.full((100, 2), 1.8))
    for _ in range(3):
        rec.record(*left)
    for _ in range(6):
        rec.record(*right)
    w = rec.workload()
    assert w[1] > w[0]                           # shifted mass dominates


# ------------------------------------------------------------ swap engine

class _ConstEngine(QueryEngine):
    name = "const"

    def __init__(self, val):
        self.val = val

    def batch(self, s, t, bucket: int = 0):
        return np.full(len(s), self.val, np.float32)

    def device_bytes(self) -> int:
        return 100


def test_swappable_engine_generations_and_drain():
    a, b = _ConstEngine(1.0), _ConstEngine(2.0)
    sw = SwappableEngine(a)
    assert sw.generation == 0
    z = np.zeros((3, 2), np.float32)
    assert (sw.batch(z, z) == 1.0).all()

    cm = sw.pin()
    eng = cm.__enter__()                 # in-flight request pinned to gen 0
    assert eng is a
    sw.swap(b)
    assert sw.generation == 1 and sw.swaps == 1
    # the pinned request still runs on the old artifact...
    assert (eng.batch(z, z) == 1.0).all()
    # ...while new requests see the new one
    assert (sw.batch(z, z) == 2.0).all()
    assert sw.retired_generations() == [0]       # old engine parked, alive
    assert sw.drops == 0
    cm.__exit__(None, None, None)                # drain
    assert sw.retired_generations() == []
    assert sw.drops == 1                         # device buffers released

    # swap with nothing pinned drops the old engine immediately
    sw.swap(_ConstEngine(3.0))
    assert sw.drops == 2 and sw.generation == 2


def test_serve_stats_two_generation_reset():
    """First request on a new generation restarts per-bucket stats (bucket
    ids are meaningless across artifacts) and counts the swap."""
    a, b = _ConstEngine(1.0), _ConstEngine(2.0)
    sw = SwappableEngine(a)
    srv = PathServer(sw, batch_size=4)
    z = np.zeros((6, 2), np.float32)
    assert (srv.query(z, z) == 1.0).all()
    assert srv.stats.generation == 0 and srv.stats.swaps == 0
    pb0 = srv.stats.per_bucket[0]
    assert pb0.queries == 6

    sw.swap(b)
    assert srv.stats.swaps == 0          # observed at next dispatch, not eagerly
    assert (srv.query(z, z) == 2.0).all()
    assert srv.stats.generation == 1 and srv.stats.swaps == 1
    assert srv.stats.per_bucket[0] is not pb0    # reset, not accumulated
    assert srv.stats.per_bucket[0].queries == 6
    for bstats in srv.stats.per_bucket.values():
        assert bstats.occupancy <= 1.0


def test_serve_stats_stale_batches_mid_request_swap():
    """A swap published while a request is in flight: every batch of that
    request finishes on the pinned (now superseded) artifact and is counted
    stale; the generation advances only on the next request."""
    a, b = _ConstEngine(1.0), _ConstEngine(2.0)
    sw = SwappableEngine(a)
    fired = []
    orig = a.batch

    def batch_then_swap(s, t, bucket=0):
        out = orig(s, t, bucket)
        if not fired:
            fired.append(True)
            sw.swap(b)               # mid-request publish
        return out

    a.batch = batch_then_swap
    srv = PathServer(sw, batch_size=4)
    z = np.zeros((6, 2), np.float32)
    out = srv.query(z, z)
    assert (out == 1.0).all()        # the whole request served on its pin
    assert srv.stats.stale_batches == 2          # both batches superseded
    assert srv.stats.generation == 0             # generation it served on
    assert (srv.query(z, z) == 2.0).all()        # next request: new artifact
    assert srv.stats.swaps == 1 and srv.stats.generation == 1
    assert srv.stats.stale_batches == 2          # no new staleness


# ---------------------------------------------------------------- planner

def test_planner_decisions(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.5)
    compress_to_device_budget(idx, budget)
    rec = WorkloadRecorder.for_index(idx)
    pl = BudgetPlanner(budget, min_queries=50, replan_threshold=0.15)

    # too little traffic, artifact fits -> skip
    assert pl.decide(rec, idx).kind == "skip"
    # budget shrinks below the artifact -> incremental resume even with no
    # fresh traffic
    pl.set_budget(int(budget * 0.6))
    dec = pl.decide(rec, idx)
    assert dec.kind == "incremental"
    st = pl.execute(dec, idx, rec)
    assert st.device_bytes <= pl.device_budget_bytes
    assert bucketed_device_bytes(idx) <= pl.device_budget_bytes
    # now enough clustered traffic -> drift forces a replan
    qs = cluster_queries(scene_s, graph_s, 2, 80, seed=21, require_path=False)
    rec.record(qs.s, qs.t)
    dec2 = pl.decide(rec, idx)
    assert dec2.kind == "replan" and dec2.drift >= 0.15
    with pytest.raises(ValueError):
        pl.execute(dec2, idx, rec, base_snapshot=None)


# ------------------------------------------------------------- hysteresis

class _FakeRecorder:
    """Drives decide() with a hand-set distribution (drift = TV distance)."""

    def __init__(self):
        self.queries = 0
        self._base = np.array([1.0, 0.0])
        self._dist = self._base.copy()

    def set_drift(self, x: float) -> None:
        """TV distance exactly ``x`` vs the last published distribution."""
        self._dist = self._base + np.array([-x, x])

    def rebase(self) -> None:
        """A plan was published from the current distribution."""
        self._base = self._dist.copy()

    def distribution(self) -> np.ndarray:
        return self._dist.copy()

    def scores(self) -> np.ndarray:
        return np.ones_like(self._dist)


def _publish(pl: BudgetPlanner, rec: _FakeRecorder) -> None:
    """Simulate a swapped candidate built from the current workload."""
    pl._pending = (rec.distribution(), rec.queries)
    pl.commit()
    rec.rebase()


def test_planner_min_dwell_stops_swap_churn(ehl_s):
    """Drift hovering at the replan threshold fires once per dwell window,
    not once per decision — the churn case the hysteresis exists for."""
    budget = bucketed_device_bytes(ehl_s) * 2        # artifact always fits
    pl = BudgetPlanner(budget, min_queries=10, replan_threshold=0.15,
                       exit_threshold=0.05, min_dwell=3)
    rec = _FakeRecorder()
    rec.queries = 20
    assert pl.decide(rec, ehl_s).kind == "replan"    # no baseline yet
    _publish(pl, rec)

    # 12 decisions with drift oscillating just around the threshold
    replans = 0
    for i in range(12):
        rec.set_drift(0.16 if i % 2 == 0 else 0.14)
        rec.queries += 20
        dec = pl.decide(rec, ehl_s)
        assert dec.kind in ("replan", "skip")
        if dec.kind == "replan":
            replans += 1
            _publish(pl, rec)
        else:
            assert "dwelling" in dec.reason
    # without hysteresis every 0.16 reading (6 of them) would fire; the
    # dwell window bounds the rate to one per (min_dwell + 1) decisions
    assert replans == 3


def test_planner_alarm_latches_through_midband_dip(ehl_s):
    """A spike over the enter threshold during dwell still replans after
    the window even if drift has dipped into the (exit, enter) band."""
    budget = bucketed_device_bytes(ehl_s) * 2
    pl = BudgetPlanner(budget, min_queries=10, replan_threshold=0.15,
                       exit_threshold=0.05, min_dwell=2)
    rec = _FakeRecorder()
    rec.queries = 20
    assert pl.decide(rec, ehl_s).kind == "replan"
    _publish(pl, rec)

    rec.set_drift(0.20)                      # alarm raises, dwell blocks
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "skip"
    rec.set_drift(0.10)                      # dip below enter: still latched
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "skip"
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "replan"    # dwell over, latched


def test_planner_exit_threshold_disarms(ehl_s):
    """Mid-band drift never replans unless the alarm was raised first, and
    falling to the exit threshold clears a raised alarm."""
    budget = bucketed_device_bytes(ehl_s) * 2
    pl = BudgetPlanner(budget, min_queries=10, replan_threshold=0.15,
                       exit_threshold=0.05, min_dwell=0)
    rec = _FakeRecorder()
    rec.queries = 20
    assert pl.decide(rec, ehl_s).kind == "replan"
    _publish(pl, rec)

    rec.set_drift(0.10)                      # mid-band, never alarmed
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "skip"
    rec.set_drift(0.16)                      # alarm
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "replan"    # min_dwell=0: fires
    # NOT published (e.g. candidate aborted): alarm stays latched
    rec.set_drift(0.10)
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "replan"    # retry while latched
    rec.set_drift(0.04)                      # at/below exit: disarms
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "skip"
    rec.set_drift(0.10)                      # mid-band again: still calm
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "skip"


def test_planner_budget_overflow_bypasses_dwell(ehl_s):
    """Holding the device budget outranks churn control: an over-budget
    artifact triggers incremental even inside the dwell window."""
    budget = bucketed_device_bytes(ehl_s) * 2
    pl = BudgetPlanner(budget, min_queries=10, replan_threshold=0.15,
                       exit_threshold=0.05, min_dwell=5)
    rec = _FakeRecorder()
    rec.queries = 20
    assert pl.decide(rec, ehl_s).kind == "replan"
    _publish(pl, rec)
    rec.set_drift(0.20)                      # alarmed + dwelling
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "skip"
    pl.set_budget(1000)                      # budget collapses under artifact
    rec.queries += 20
    assert pl.decide(rec, ehl_s).kind == "incremental"


# ------------------------------------------------- manager / hot swap

@pytest.fixture(scope="module")
def adaptive_setup(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.45)
    mgr = IndexManager(idx, budget, batch_size=32, min_queries=60,
                       replan_threshold=0.10, probe_n=32, seed=13)
    srv = PathServer(mgr.engine, batch_size=32, recorder=mgr.recorder)
    srv.warmup()
    return mgr, srv, budget


def test_hot_swap_answers_identical_and_budget_held(adaptive_setup,
                                                    scene_s, graph_s):
    """The acceptance gate: a fixed probe set answers identically right
    before and right after a swap, and the swapped-in artifact fits the
    configured device-byte budget."""
    mgr, srv, budget = adaptive_setup
    assert mgr.device_bytes() <= budget          # initial fit

    qs = cluster_queries(scene_s, graph_s, 2, 150, seed=31,
                         require_path=False)
    srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))

    ps, pt = mgr.probe_set()
    d_before = mgr.probe_answers()
    _, paths_before = srv.query_paths(ps[:12], pt[:12],
                                      host_index=mgr.host_index)
    gen0 = mgr.generation

    assert mgr.maybe_adapt() is True             # swap published
    assert mgr.generation == gen0 + 1
    assert mgr.validation_failures == 0

    d_after = mgr.probe_answers()
    both_inf = ~np.isfinite(d_before) & ~np.isfinite(d_after)
    np.testing.assert_array_equal(np.where(both_inf, 0, d_before),
                                  np.where(both_inf, 0, d_after))
    assert mgr.device_bytes() <= budget          # budget survives the swap

    _, paths_after = srv.query_paths(ps[:12], pt[:12],
                                     host_index=mgr.host_index)
    for pb, pa in zip(paths_before, paths_after):
        assert len(pb) == len(pa)
        if len(pb):
            np.testing.assert_allclose(np.asarray(pb), np.asarray(pa),
                                       atol=1e-5)


def test_adaptive_join_cost_no_worse_than_uniform(scene_s, graph_s, hl_s):
    """Post-swap expected join cost (mean dispatch-width^2) on a Cluster-x
    workload must be <= the uniform-score index at the same budget."""
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.35)
    mgr = IndexManager(idx, budget, batch_size=32, min_queries=60,
                       replan_threshold=0.10, probe_n=16, seed=3)
    uniform = mgr.engine.current

    qs = cluster_queries(scene_s, graph_s, 2, 200, seed=41,
                         require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    mgr.recorder.record(s, t)
    assert mgr.maybe_adapt() is True

    def join_cost(eng):
        buckets = eng.buckets_of(s, t)
        widths = np.array([eng.bucket_width(int(k)) for k in buckets])
        return float(np.mean(widths.astype(np.float64) ** 2))

    assert join_cost(mgr.engine.current) <= join_cost(uniform)


def test_serve_stats_track_generation(adaptive_setup, scene_s, graph_s):
    mgr, srv, _ = adaptive_setup
    qs = cluster_queries(scene_s, graph_s, 2, 80, seed=51,
                         require_path=False)
    srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
    assert srv.stats.generation == mgr.generation
    assert srv.stats.swaps >= mgr.swaps - 1      # observed via dispatches


def test_background_adapt_thread(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.5)
    mgr = IndexManager(idx, budget, batch_size=16, min_queries=40,
                       replan_threshold=0.10, probe_n=8, seed=29)
    qs = cluster_queries(scene_s, graph_s, 2, 60, seed=61,
                         require_path=False)
    mgr.recorder.record(qs.s, qs.t)
    assert mgr.maybe_adapt(block=False) is False  # runs on the thread
    mgr.join(timeout=120.0)
    assert mgr.swaps == 1 and mgr.validation_failures == 0
    assert mgr.device_bytes() <= budget


def test_aborted_swap_rolls_back_mirror_and_planner(scene_s, graph_s, hl_s):
    """A rejected candidate must leave no trace: host_index (the unwinding
    mirror of the live artifact) is restored and the planner keeps measuring
    drift against the last *published* plan, so adaptation retries instead
    of wedging on 'skip'."""
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.5)
    mgr = IndexManager(idx, budget, batch_size=16, min_queries=40,
                       replan_threshold=0.10, probe_n=8, seed=5)
    mapper_before = np.asarray(mgr.host_index.mapper).copy()
    n_regions = len(mgr.host_index.regions)

    # an unreachable budget: the candidate can never fit, so the budget
    # gate added after probe validation must abort the swap
    mgr.set_budget(10_000)
    assert mgr.maybe_adapt() is False
    assert mgr.generation == 0 and mgr.swaps == 0
    assert mgr.validation_failures == 1
    assert mgr.history[-1].swapped is False
    assert "over device budget" in mgr.history[-1].abort_reason
    # mirror rolled back to the live artifact's region partition
    assert len(mgr.host_index.regions) == n_regions
    np.testing.assert_array_equal(np.asarray(mgr.host_index.mapper),
                                  mapper_before)
    # planner baseline untouched: it still wants to act, not 'skip'
    assert mgr.planner.decide(mgr.recorder, mgr.host_index).kind != "skip"

    # restoring a feasible budget lets the same manager adapt normally
    mgr.set_budget(budget)
    qs = cluster_queries(scene_s, graph_s, 2, 60, seed=71,
                         require_path=False)
    mgr.recorder.record(qs.s, qs.t)
    assert mgr.maybe_adapt() is True
    assert mgr.device_bytes() <= budget


def test_incremental_resume_preserves_answers(scene_s, graph_s, hl_s,
                                              queries_s):
    """compress_incremental on an already-merged index keeps every answer
    (merging is correctness-preserving from any start state)."""
    from repro.core.compression import compress_incremental, \
        compress_to_fraction
    from repro.core.query import query

    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    compress_to_fraction(idx, 0.5)
    truth = [query(idx, s, t, want_path=False)[0]
             for s, t in zip(queries_s.s[:15], queries_s.t[:15])]
    st = compress_incremental(idx, int(idx.label_memory() * 0.5))
    assert st.merges > 0
    assert st.final_bytes <= st.budget or st.hit_single_region
    for (s, t), d0 in zip(zip(queries_s.s[:15], queries_s.t[:15]), truth):
        d, _ = query(idx, s, t, want_path=False)
        assert d == pytest.approx(d0, abs=1e-8)
