"""Continuous batcher: coalescing, flush reasons, backpressure, swap safety.

Most tests drive the :class:`~repro.serving.batcher.CoalescingBatcher`
through a deterministic multi-key fake engine (no device work, no timing
flakiness); the identity tests at the bottom go through the real packed
engine against the synchronous ``PathServer`` path.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import pack_bucketed
from repro.indexing import SwappableEngine
from repro.serving.batcher import CoalescingBatcher, QueueFull
from repro.serving.engine import PathServer
from repro.serving.query_engine import JnpEngine, QueryEngine


class _KeyedEngine(QueryEngine):
    """Deterministic 4-key engine: answer = s.x + 1000 * val.

    Routing depends only on the query (floor of s.x mod 4), so expected
    answers are computable without the engine — coalescing/scatter bugs
    show up as wrong values, not just wrong stats.
    """

    name = "keyed"
    static_shapes = True
    num_buckets = 4

    def __init__(self, val: float = 0.0):
        self.val = val
        self.dispatched = []        # (bucket, rows) per batch() call

    def buckets_of(self, s, t):
        return (np.asarray(s)[:, 0].astype(np.int64) % 4).astype(np.int32)

    def bucket_width(self, bucket: int) -> int:
        return 128

    def batch(self, s, t, bucket: int = 0):
        self.dispatched.append((bucket, len(s)))
        return (np.asarray(s)[:, 0] + 1000.0 * self.val).astype(np.float32)

    def batch_argmin(self, s, t, bucket: int = 0):
        d = self.batch(s, t, bucket)
        z = np.zeros(len(d), np.int32)
        return d, z, z, z, z


def _mk(val=0.0, batch_size=8, **kw):
    srv = PathServer(_KeyedEngine(val), batch_size=batch_size)
    kw.setdefault("autostart", False)
    return srv, CoalescingBatcher(srv, **kw)


def _pts(xs):
    xs = np.asarray(xs, np.float32)
    return np.stack([xs, np.zeros_like(xs)], axis=1)


def _expect(xs, val=0.0):
    return np.asarray(xs, np.float32) + np.float32(1000.0 * val)


# ------------------------------------------------------------ flush reasons

def test_full_batch_flush_and_identity():
    srv, b = _mk(batch_size=8)
    xs = np.full(8, 4.0) + np.arange(8) * 4      # all key 0, fills exactly
    tk = b.submit(_pts(xs), _pts(xs))
    b.start()
    out = tk.result(timeout=10)
    b.close()
    np.testing.assert_array_equal(out, _expect(xs))
    assert srv.stats.full_flushes == 1
    assert srv.stats.deadline_flushes == 0
    assert srv.stats.per_bucket[0].full_flushes == 1
    assert srv.stats.per_bucket[0].slots == 8
    assert srv.stats.per_bucket[0].occupancy == 1.0


def test_deadline_flush_ships_partial_group():
    srv, b = _mk(batch_size=8, max_wait_ms=5.0, autostart=True)
    xs = np.array([4.0, 8.0, 12.0])              # key 0, under batch_size
    t0 = time.perf_counter()
    tk = b.submit(_pts(xs), _pts(xs))
    out = tk.result(timeout=10)                  # only the deadline ships it
    waited = time.perf_counter() - t0
    b.close()
    np.testing.assert_array_equal(out, _expect(xs))
    assert waited >= 0.004                       # not shipped early
    assert srv.stats.deadline_flushes == 1
    assert srv.stats.full_flushes == 0
    assert srv.stats.per_bucket[0].deadline_flushes == 1


def test_forced_flush_overrides_deadline():
    srv, b = _mk(batch_size=8, max_wait_ms=60_000.0, autostart=True)
    tk = b.submit(_pts([4.0]), _pts([4.0]))
    b.flush()
    out = tk.result(timeout=10)                  # long before the deadline
    b.close()
    np.testing.assert_array_equal(out, _expect([4.0]))
    assert srv.stats.forced_flushes == 1
    assert srv.stats.deadline_flushes == 0


def test_mixed_keys_coalesce_across_submits():
    """Interleaved keys from many submits regroup into per-key full batches
    and scatter back to each ticket in submit order."""
    srv, b = _mk(batch_size=8)
    rng = np.random.default_rng(3)
    xs = rng.integers(0, 64, size=48).astype(np.float32)   # keys 0..3 mixed
    tickets = [b.submit(_pts(xs[i:i + 3]), _pts(xs[i:i + 3]))
               for i in range(0, 48, 3)]
    b.start()
    b.flush()
    assert b.drain(timeout=10)
    b.close()
    for i, tk in enumerate(tickets):
        np.testing.assert_array_equal(tk.result(timeout=1),
                                      _expect(xs[3 * i:3 * i + 3]))
    eng = srv.engine
    # coalescing: every dispatched batch holds a single key's queries
    keys = (np.asarray(xs).astype(np.int64) % 4)
    per_key = {k: int((keys == k).sum()) for k in range(4)}
    batches = sum(-(-n // 8) for n in per_key.values())
    assert len(eng.dispatched) == batches
    for k, bstats in srv.stats.per_bucket.items():
        assert bstats.admitted == per_key[k]
        assert bstats.queries == per_key[k]
        assert bstats.occupancy <= 1.0


def test_argmin_tickets_round_trip():
    srv, b = _mk(batch_size=8)
    xs = np.array([4.0, 5.0, 6.0])
    tk = b.submit(_pts(xs), _pts(xs), want_argmin=True)
    b.start()
    b.flush()
    out = tk.result(timeout=10)
    b.close()
    assert len(out) == 5
    np.testing.assert_array_equal(out[0], _expect(xs))
    # distance-only and argmin groups must not share a dispatch even on
    # the same routing key
    assert srv.stats.batches == 3       # keys 0,1,2 x one argmin group each


# ------------------------------------------------------------- backpressure

def test_backpressure_shed_raises_queue_full():
    srv, b = _mk(batch_size=8, max_queue=4, policy="shed")
    b.submit(_pts([0.0, 1.0]), _pts([0.0, 1.0]))
    with pytest.raises(QueueFull):
        b.submit(_pts([2.0, 3.0, 4.0]), _pts([2.0, 3.0, 4.0]))
    assert srv.stats.shed == 3
    assert srv.stats.submitted == 2          # rejected queries not admitted
    assert b.queue_depth == 2


def test_backpressure_block_waits_for_drain():
    srv, b = _mk(batch_size=4, max_queue=4, policy="block",
                 max_wait_ms=5.0, autostart=True)
    xs = np.arange(12, dtype=np.float32) * 4     # key 0: three full batches
    done = []

    def feed():
        for lo in range(0, 12, 4):               # 2nd/3rd chunk must wait
            done.append(b.submit(_pts(xs[lo:lo + 4]), _pts(xs[lo:lo + 4])))

    th = threading.Thread(target=feed)
    th.start()
    th.join(timeout=10)
    assert not th.is_alive()
    out = np.concatenate([tk.result(timeout=10) for tk in done])
    b.close()
    np.testing.assert_array_equal(out, _expect(xs))
    assert srv.stats.admission_waits >= 1
    assert srv.stats.queue_depth_peak <= 4


# ---------------------------------------------------------------- pipeline

def test_double_buffer_keeps_two_groups_in_flight():
    srv, b = _mk(batch_size=8, depth=2)
    xs = np.full(24, 4.0) + np.arange(24) * 4    # key 0: three full groups
    tk = b.submit(_pts(xs), _pts(xs))
    b.start()
    out = tk.result(timeout=10)
    b.close()
    np.testing.assert_array_equal(out, _expect(xs))
    assert srv.stats.pipeline_peak == 2
    assert srv.stats.full_flushes == 3


# -------------------------------------------------------------- swap safety

def test_superseded_group_requeues_without_slot_accounting():
    """A group admitted under generation 0 but dispatched after a swap is
    re-routed under the live generation: answered by the new engine, one
    requeue counted, and the per-bucket slot accounting never sees the
    aborted dispatch (occupancy stays <= 1)."""
    old, new = _KeyedEngine(1.0), _KeyedEngine(2.0)
    sw = SwappableEngine(old)
    srv = PathServer(sw, batch_size=8)
    b = CoalescingBatcher(srv, autostart=False)
    xs = np.full(8, 4.0) + np.arange(8) * 4
    tk = b.submit(_pts(xs), _pts(xs))            # queued under gen 0
    sw.swap(new)                                 # published before dispatch
    b.start()
    out = tk.result(timeout=10)
    b.close()
    np.testing.assert_array_equal(out, _expect(xs, 2.0))   # new engine wins
    assert old.dispatched == []                  # stale gen never dispatched
    assert srv.stats.requeued_batches == 1
    assert srv.stats.generation == 1 and srv.stats.swaps == 1
    bstats = srv.stats.per_bucket[0]
    assert bstats.batches == 1 and bstats.slots == 8
    assert bstats.occupancy <= 1.0


def test_inflight_batch_finishes_on_pinned_generation():
    """A swap published while a batch computes: the batch finishes on the
    engine it pinned (old answers) and is counted stale, the next group
    serves on the new generation."""
    old, new = _KeyedEngine(1.0), _KeyedEngine(2.0)
    sw = SwappableEngine(old)
    srv = PathServer(sw, batch_size=8)
    swap_once = []

    orig = old.batch

    def swapping_batch(s, t, bucket=0):
        out = orig(s, t, bucket)
        if not swap_once:
            swap_once.append(True)
            sw.swap(new)                 # mid-dispatch publish
        return out

    old.batch = swapping_batch
    b = CoalescingBatcher(srv, autostart=False)
    xs = np.full(8, 4.0) + np.arange(8) * 4
    tk1 = b.submit(_pts(xs), _pts(xs))
    b.start()
    out1 = tk1.result(timeout=10)
    tk2 = b.submit(_pts(xs), _pts(xs))
    out2 = tk2.result(timeout=10)
    b.close()
    np.testing.assert_array_equal(out1, _expect(xs, 1.0))  # pinned gen 0
    np.testing.assert_array_equal(out2, _expect(xs, 2.0))  # live gen 1
    assert srv.stats.stale_batches == 1
    assert srv.stats.swaps == 1 and srv.stats.generation == 1
    for bstats in srv.stats.per_bucket.values():
        assert bstats.occupancy <= 1.0


# -------------------------------------------------------- real-engine path

@pytest.fixture(scope="module")
def real_server(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    compress_to_fraction(idx, 0.3)
    srv = PathServer(JnpEngine(pack_bucketed(idx)), batch_size=16)
    srv.warmup(paths=True)
    return srv


def test_async_matches_sync_bitwise(real_server, queries_s):
    srv = real_server
    s = queries_s.s.astype(np.float32)
    t = queries_s.t.astype(np.float32)
    ref = srv.query(s, t)
    tickets = [srv.submit(s[i], t[i]) for i in range(len(s))]
    srv.flush()
    assert srv.drain(timeout=60)
    got = np.concatenate([tk.result(timeout=1) for tk in tickets])
    srv.stop_async()
    np.testing.assert_array_equal(ref, got)      # bitwise, padding-invariant
    for bstats in srv.stats.per_bucket.values():
        assert bstats.occupancy <= 1.0


def test_async_argmin_matches_sync_bitwise(real_server, queries_s):
    srv = real_server
    s = queries_s.s[:12].astype(np.float32)
    t = queries_s.t[:12].astype(np.float32)
    ref = srv._dispatch(s, t, want_argmin=True)
    tk = srv.submit(s, t, want_argmin=True)
    srv.flush()
    got = tk.result(timeout=60)
    srv.stop_async()
    assert len(got) == 5
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
