"""Per-architecture smoke tests: reduced config, fwd + train step + decode.

One test class per assigned architecture (brief requirement): instantiate a
REDUCED config of the same family, run one forward and one train step on CPU,
assert output shapes and finiteness; decode agreement is covered for each
family representative (cheaper than all 10 every run).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, apply_updates, init_state

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    enc = (jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model),
                             dtype=jnp.float32) if cfg.encdec else None)
    return toks, enc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, dtype=jnp.float32)
    toks, enc = _inputs(cfg)

    logits = T.forward(cfg, params, toks, enc_frames=enc)
    assert logits.shape == (*toks.shape, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, toks, enc_frames=enc))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.abs(g)) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0

    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    st = init_state(params, ocfg)
    new_params, st, metrics = apply_updates(params, grads, st, ocfg)
    loss2 = T.loss_fn(cfg, new_params, toks, enc_frames=enc)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss) + 1.0   # step didn't explode


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",        # plain GQA
    "deepseek-v3-671b",      # MLA absorbed decode + MoE + dense lead-in
    "gemma2-27b",            # local/global windows + softcaps
    "mamba2-780m",           # SSD state decode
    "hymba-1.5b",            # hybrid parallel heads
    "whisper-large-v3",      # enc-dec cross-attention cache
])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, dtype=jnp.float32)
    B, S = 2, 16
    toks, enc = _inputs(cfg, B, S)
    full = T.forward(cfg, params, toks, enc_frames=enc)
    enc_out = T.encode(cfg, params, enc) if cfg.encdec else None
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32, enc_out=enc_out,
                         params=params)
    outs = []
    for i in range(S):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_moe_routes_to_multiple_experts():
    cfg = get_config("llama4-scout-17b-a16e").reduced()
    params = T.init_params(cfg, KEY, dtype=jnp.float32)
    toks, _ = _inputs(cfg, 4, 64)
    # grads flowing to >1 expert proves routing is not collapsed
    g = jax.grad(lambda p: T.loss_fn(cfg, p, toks))(params)
    per_expert = jnp.abs(g["blocks"]["moe"]["wg"]).sum(axis=(0, 2, 3))
    assert int((per_expert > 0).sum()) >= 2


def test_gemma_window_pattern():
    cfg = get_config("gemma2-27b")
    pattern = [cfg.layer_is_global(l) for l in range(6)]
    assert pattern == [False, True, False, True, False, True]
    cfg3 = get_config("gemma3-12b")
    p3 = [cfg3.layer_is_global(l) for l in range(12)]
    assert p3 == [False] * 5 + [True] + [False] * 5 + [True]


def test_param_counts_match_published():
    expect = {
        "deepseek-v3-671b": (671e9, 0.02),
        "nemotron-4-340b": (341e9, 0.02),
        "gemma2-27b": (27.2e9, 0.05),
        "tinyllama-1.1b": (1.1e9, 0.05),
        "qwen2-vl-72b": (72.7e9, 0.05),
        "mamba2-780m": (0.78e9, 0.05),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n)


def test_mtp_loss_path():
    cfg = get_config("deepseek-v3-671b").reduced()
    assert cfg.mtp
    params = T.init_params(cfg, KEY, dtype=jnp.float32)
    toks, _ = _inputs(cfg, 2, 16)
    loss = T.loss_fn(cfg, params, toks)
    assert bool(jnp.isfinite(loss))
