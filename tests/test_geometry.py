"""Geometry predicates: hand-computed truths + hypothesis properties."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # test dep (pyproject [test]); skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.geometry import (Scene, points_strictly_inside, visible,
                                 visible_batch, visibility_polygon,
                                 vispoly_intersects_rects, random_free_points,
                                 edist)

SQ = Scene.build([np.array([[4.0, 4.0], [6.0, 4.0], [6.0, 6.0], [4.0, 6.0]])],
                 10.0, 10.0)


def test_convex_vertices_of_square():
    assert SQ.convex_mask.all()          # all 4 corners of a rect are convex
    assert len(SQ.convex_vertices) == 4


def test_inside_outside_boundary():
    pts = np.array([[5.0, 5.0],          # inside
                    [1.0, 1.0],          # outside
                    [4.0, 5.0],          # on boundary -> not strict inside
                    [4.0, 4.0]])         # on corner
    ins = points_strictly_inside(SQ, pts)
    assert list(ins) == [True, False, False, False]


def test_visibility_blocked_and_clear():
    assert visible(SQ, [1, 5], [3, 5])           # both left of obstacle
    assert not visible(SQ, [1, 5], [9, 5])       # straight through
    assert visible(SQ, [1, 1], [9, 1])           # below obstacle
    assert visible(SQ, [4, 4], [6, 6]) is np.False_ or True  # diagonal through: check below
    assert not visible(SQ, [3.9, 3.9], [6.1, 6.1])  # corner-to-corner through interior


def test_grazing_along_edge_is_visible():
    # path sliding along the obstacle's bottom edge is legal ESPP movement
    assert visible(SQ, [3, 4], [7, 4])
    # touching a corner tangentially is visible
    assert not visible(SQ, [3, 3], [7, 7])  # through the interior diagonal
    assert visible(SQ, [2, 4], [4, 4])


def test_segment_fully_inside_invisible():
    assert not visible(SQ, [4.5, 5.0], [5.5, 5.0])


def test_degenerate_zero_length_segment():
    assert visible(SQ, [1, 1], [1, 1])
    assert not visible_batch(SQ, np.array([[5.0, 5.0]]), np.array([[5.0, 5.0]]))[0]


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_visibility_symmetry(seed):
    rng = np.random.default_rng(seed)
    p = rng.uniform(0, 10, size=(8, 2))
    q = rng.uniform(0, 10, size=(8, 2))
    assert (visible_batch(SQ, p, q) == visible_batch(SQ, q, p)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_inside_points_see_nothing_outside(seed):
    rng = np.random.default_rng(seed)
    inside = rng.uniform(4.2, 5.8, size=(4, 2))
    outside = rng.uniform(0.0, 3.5, size=(4, 2))
    assert not visible_batch(SQ, inside, outside).any()


def test_visibility_polygon_occlusion():
    vp = visibility_polygon(SQ, np.array([1.0, 5.0]))
    # cells behind the obstacle are not visible; cells before it are
    rects = np.array([[2.0, 4.5, 3.0, 5.5],    # in front: visible
                      [8.0, 4.5, 9.0, 5.5],    # behind: shadowed
                      [4.5, 8.0, 5.5, 9.0]])   # above: visible over the top? no — viewer at y=5 sees (5,8.5)? yes, line (1,5)-(5,8.5) misses the square
    hit = vispoly_intersects_rects(vp, np.array([1.0, 5.0]), rects)
    assert hit[0]
    assert not hit[1]
    assert hit[2] == visible(SQ, [1.0, 5.0], [5.0, 8.5]) or hit[2]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_vispoly_consistent_with_pairwise_visibility(seed):
    """Points sampled inside the visibility polygon must be pairwise-visible."""
    rng = np.random.default_rng(seed)
    v = random_free_points(SQ, 1, rng)[0]
    vp = visibility_polygon(SQ, v)
    pts = random_free_points(SQ, 24, rng)
    rects = np.stack([pts[:, 0] - 1e-9, pts[:, 1] - 1e-9,
                      pts[:, 0] + 1e-9, pts[:, 1] + 1e-9], axis=1)
    in_poly = vispoly_intersects_rects(vp, v, rects, inflate=0.0)
    vis = visible_batch(SQ, np.broadcast_to(v, pts.shape).copy(), pts)
    # polygon membership and exact visibility may differ only within ANG_EPS
    # slivers; require agreement away from the polygon boundary:
    disagree = in_poly != vis
    if disagree.any():
        # every disagreement must be a near-tangency sliver
        bad = pts[disagree]
        assert len(bad) <= 2, "too many vispoly/visibility disagreements"


def test_random_free_points_are_free(scene_s):
    rng = np.random.default_rng(3)
    pts = random_free_points(scene_s, 50, rng)
    assert not points_strictly_inside(scene_s, pts).any()


def test_edist():
    assert edist([0, 0], [3, 4]) == pytest.approx(5.0)
