"""Quantized slab encoding edge cases + exact-argmin guarantee (DESIGN §11).

Unit-level: the delta-u16 id encoder and narrow-dtype distance encoder
must fall back *loudly* (per-bucket raw dtypes surfaced by
``quant_stats``) instead of silently corrupting ids or distances; the
ambiguity margin in the argmin join must flag exact ties.  Property-level:
a seeded multi-scene sweep asserting the quantized engine's argmin winners
are bitwise-identical to the f32 engine after residual rescue.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.packed import (bucketed_device_bytes, encode_delta_u16,
                               encode_dist, join_masked,
                               query_batch_bucketed, slab_layout,
                               _quant_stats, _quantize_slab)

F16 = slab_layout("f16").dist_dtype
BF16 = slab_layout("bf16").dist_dtype


# ---------------------------------------------------------------------------
# id encoding: u16 delta + loud i32 fallback
# ---------------------------------------------------------------------------

def test_delta_u16_roundtrip_with_pads():
    ids = np.array([[7, 100, -1, 65541], [0, 0, -1, -1]], np.int32)
    valid = ids >= 0
    enc, base = encode_delta_u16(ids, valid)
    assert enc.dtype == np.uint16 and base.dtype == np.int32
    assert (enc[~valid] == 0xFFFF).all()          # pad sentinel
    dec = base[:, None].astype(np.int64) + enc
    np.testing.assert_array_equal(dec[valid], ids[valid])


def test_delta_u16_range_overflow_returns_none():
    # per-row range 70000 > 0xFFFE: no u16 encoding exists without lossy
    # clamping, so the encoder must refuse rather than wrap
    ids = np.array([[5, 70005]], np.int64)
    enc, base = encode_delta_u16(ids, np.ones_like(ids, bool))
    assert enc is None and base is None
    # large *absolute* ids with a small range are fine (delta vs row base)
    ids = np.array([[1_000_000, 1_000_002]], np.int64)
    enc, base = encode_delta_u16(ids, np.ones_like(ids, bool))
    assert enc is not None and int(base[0]) == 1_000_000


def test_quantize_slab_id_fallback_is_loud():
    lay = slab_layout("bf16")
    R, W = 2, 4
    xy = np.zeros((R, W, 2), np.float32)
    d = np.full((R, W), 1.5, np.float32)
    wide_hub = np.array([[0, 80_000, -1, -1]] * R, np.int32)   # range > u16
    vid = np.tile(np.arange(W, dtype=np.int32), (R, 1))        # range ok
    hub_q, d_q, vid_q, hub_base, vid_base, qerr = _quantize_slab(
        (wide_hub, xy, d, vid), lay)
    assert hub_q.dtype == np.int32           # fell back, ids untouched
    np.testing.assert_array_equal(hub_q, wide_hub)
    assert vid_q.dtype == np.uint16          # independent planes
    assert d_q.dtype == BF16
    # the fallback is observable per bucket, never silent
    st = _quant_stats(lay, [hub_q, vid_q.view(np.uint16)], [d_q], [vid_q],
                      qerr)
    assert st["id_fallback"] == (True, False)
    assert st["dist_fallback"] == (False,)


# ---------------------------------------------------------------------------
# distance encoding: overflow + subnormals, f16 vs bf16
# ---------------------------------------------------------------------------

def test_encode_dist_f16_finite_overflow_falls_back():
    d = np.array([1.0, 70_000.0, np.inf], np.float32)   # f16 max is 65504
    dq, qerr = encode_dist(d, F16)
    assert dq is None and qerr == 0.0
    dq, qerr = encode_dist(d, BF16)                     # bf16 reaches 3e38
    assert dq is not None
    back = dq.astype(np.float32)
    assert np.isinf(back[2]) and np.isfinite(back[:2]).all()
    assert np.abs(back[:2] - d[:2]).max() <= qerr


def test_encode_dist_bf16_finite_overflow_falls_back():
    # above bf16's max finite (~3.39e38) but still finite in f32
    d = np.array([np.float32(3.4e38)], np.float32)
    dq, qerr = encode_dist(d, BF16)
    assert dq is None and qerr == 0.0


@pytest.mark.parametrize("dtype", [F16, BF16], ids=["f16", "bf16"])
def test_encode_dist_subnormals_stay_in_bound(dtype):
    # values below each format's min normal (f16: 6.1e-5, bf16: 1.2e-38)
    # round through the subnormal range; qerr must still bound the error
    d = np.array([1e-5, 6.1e-5, 5e-4, 1e-40, 0.0, np.inf], np.float32)
    dq, qerr = encode_dist(d, dtype)
    assert dq is not None
    back = dq.astype(np.float32)
    fin = np.isfinite(d)
    assert np.array_equal(fin, np.isfinite(back))
    assert np.abs(back[fin] - d[fin]).max() <= qerr
    assert float(back[4]) == 0.0                        # zero is exact


# ---------------------------------------------------------------------------
# argmin ambiguity margin: exact ties must be flagged
# ---------------------------------------------------------------------------

def test_join_masked_flags_margin_ties():
    qerr2 = np.float32(0.5)       # summed per-side bound; threshold 2*qerr2
    PAD_HUB = 9                   # never matches across sides (vd is inf)
    hub = jnp.asarray(np.array([
        [0, 1, PAD_HUB, PAD_HUB],   # two candidates, margin == 2*qerr2
        [0, 1, PAD_HUB, PAD_HUB],   # two candidates, margin >> threshold
        [0, PAD_HUB, PAD_HUB, PAD_HUB],   # unique candidate
    ], np.int32))
    vd_s = jnp.asarray(np.array([
        [10.0, 11.0, np.inf, np.inf],
        [10.0, 12.0, np.inf, np.inf],
        [10.0, np.inf, np.inf, np.inf],
    ], np.float32))
    vd_t = jnp.where(jnp.isfinite(vd_s), 0.0, jnp.inf).astype(jnp.float32)
    vid_s = jnp.asarray(np.arange(12, dtype=np.int32).reshape(3, 4) + 100)
    vid_t = vid_s + 50
    s = jnp.zeros((3, 2), jnp.float32)
    t = jnp.ones((3, 2), jnp.float32)
    covis = jnp.zeros(3, bool)

    d, cv, via_s, hub_w, via_t, amb = (np.asarray(r) for r in join_masked(
        (hub, vd_s, vid_s), (hub, vd_t, vid_t), s, t, covis,
        want_argmin=True, qerr2=qerr2))
    np.testing.assert_allclose(d, [10.0, 10.0, 10.0])
    np.testing.assert_array_equal(via_s, [100, 104, 108])   # winner slot 0
    np.testing.assert_array_equal(hub_w, [0, 0, 0])
    np.testing.assert_array_equal(via_t, [150, 154, 158])
    # the margin test is inclusive: a tie exactly at 2*qerr2 could swap
    # winners in exact f32 space, so it MUST be rescued; a clear margin and
    # a unique candidate provably cannot
    np.testing.assert_array_equal(amb, [True, False, False])

    # without qerr2 the same call is the plain exact 5-tuple entry
    res = join_masked((hub, vd_s, vid_s), (hub, vd_t, vid_t), s, t, covis,
                      want_argmin=True)
    assert len(res) == 5


# ---------------------------------------------------------------------------
# property sweep: quantized argmin == f32 argmin (the rescue guarantee)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["bf16", "f16"])
def test_quantized_argmin_bitwise_matches_f32_sweep(conformance, scene_s,
                                                    layout):
    """Seeded property sweep over fresh random endpoints: for every pair,
    the quantized engine's covis verdict and via/hub winners are bitwise
    equal to f32 (ambiguous rows went through the residual), and distances
    stay inside the 2*qerr bound."""
    from repro.core.geometry import random_free_points
    bx32 = conformance.bucketed("f32")
    bxq = conformance.bucketed(layout)
    qerr = conformance.qerr(layout)
    assert qerr > 0.0
    for seed in (3, 17, 91):
        rng = np.random.default_rng(seed)
        s = random_free_points(scene_s, 16, rng).astype(np.float32)
        t = random_free_points(scene_s, 16, rng).astype(np.float32)
        ref = [np.asarray(r) for r in query_batch_bucketed(
            bx32, s, t, want_argmin=True)]
        got = [np.asarray(r) for r in query_batch_bucketed(
            bxq, s, t, want_argmin=True)]
        fin = np.isfinite(ref[0])
        assert np.array_equal(fin, np.isfinite(got[0]))
        bound = 2.0 * qerr + 1e-4 * np.abs(ref[0][fin])
        assert np.all(np.abs(got[0][fin] - ref[0][fin]) <= bound + 1e-6)
        np.testing.assert_array_equal(got[1], ref[1])
        m = ~ref[1] & fin
        for g, r in zip(got[2:], ref[2:]):
            np.testing.assert_array_equal(g[m], r[m])


@pytest.mark.parametrize("layout", ["bf16", "f16"])
def test_quantized_estimator_matches_realized_bytes(conformance, layout):
    """The planner steers by the analytic byte model — it must agree
    exactly with the realized quantized artifact (per-slot narrow planes +
    per-row bases + the shared vertex table)."""
    bx = conformance.bucketed(layout)
    est = bucketed_device_bytes(conformance.idx, layout=slab_layout(layout))
    assert est == bx.device_bytes()
    assert bx.device_bytes() < conformance.bucketed("f32").device_bytes()
